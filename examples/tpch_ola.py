"""End-to-end driver: the paper's full TPC-H evaluation workload.

All four tasks (aggregation, group-by small, large-domain group-by, join
group-by), each with the three estimation models (single / multiple /
synchronized-semantics), plus a straggler simulation and the group-by
Pallas-kernel dispatch — the paper's §5 in one script, scaled to one CPU.

    PYTHONPATH=src python examples/tpch_ola.py [rows]
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import engine, gla, randomize
from repro.data import tpch

ROWS = int(sys.argv[1]) if len(sys.argv) > 1 else 500_000
PARTS = 8
SUPPLIERS = tpch.Q1_LARGE_SUPPLIERS      # paper §5.3 scaled: 100k raw ids
BUCKET_BITS = tpch.Q1_LARGE_BUCKET_BITS  # folded into 2**13 hash buckets


def main():
    cols = tpch.generate_lineitem(ROWS, seed=5, num_suppliers=SUPPLIERS)
    cols["orderkey"] = tpch.generate_orders_fk(ROWS, seed=5)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(3),
        PARTS)
    # pad chunk count to a multiple of 8 so every run gets 8 snapshot rounds
    n_chunks = -(-ROWS // PARTS // 1024)
    shards = randomize.pack_partitions(parts, chunk_len=1024,
                                       min_chunks=-(-n_chunks // 8) * 8)
    supp, valid = tpch.supplier_nation_table(SUPPLIERS)

    def make_large(est):
        return gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
            num_groups=SUPPLIERS, bucket_bits=BUCKET_BITS,
            d_total=float(ROWS), estimator=est, num_aggs=4)

    queries = {
        "Q6 agg (low sel)": lambda est: gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            d_total=float(ROWS), estimator=est),
        "Q6 agg (high sel)": lambda est: gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_HIGH_WINDOW),
            d_total=float(ROWS), estimator=est),
        "Q1 group-by small": lambda est: gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=float(ROWS), estimator=est, num_aggs=4),
        f"Q1 group-by large ({SUPPLIERS} ids, 2^{BUCKET_BITS} buckets)":
            make_large,
        "join group-by": lambda est: gla.make_join_groupby_gla(
            tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            lambda c: c["suppkey"], supp, valid,
            num_groups=tpch.NUM_NATIONS, d_total=float(ROWS),
            estimator=est, num_aggs=4),
    }

    C = shards["_mask"].shape[1]
    rounds = 8  # C is padded to a multiple of 8 above; no divisor workaround

    for name, make in queries.items():
        print(f"\n=== {name} ===")
        for est_kind in ("single", "multiple"):
            g = make(est_kind)
            t0 = time.perf_counter()
            res = repro.run_query(
                repro.QuerySpec(g, rounds=rounds, emit="round"), shards)
            jax.block_until_ready(res.final)
            dt = time.perf_counter() - t0
            est = res.estimates
            lo = np.asarray(est.lower, np.float64)
            hi = np.asarray(est.upper, np.float64)
            mid = np.asarray(est.estimate, np.float64)
            if mid.ndim > 1:  # group-by [R, G(, A)]: busiest group, agg 0
                while mid.ndim > 2:
                    lo, hi, mid = lo[..., 0], hi[..., 0], mid[..., 0]
                gsel = int(np.argmax(np.abs(mid[-1])))
                lo, hi, mid = lo[:, gsel], hi[:, gsel], mid[:, gsel]
            w = (hi - lo) / np.maximum(np.abs(mid), 1e-12)
            print(f"  {est_kind:9s} {dt:6.2f}s  rel.width by round: "
                  + " ".join(f"{x:.3f}" for x in w))

        # straggler run: partitions at different speeds, async estimation.
        # The large-domain state is too big for per-chunk prefixes, so it
        # takes the masked-rescan path; everything else keeps emit="chunk".
        sched = engine.straggler_schedule(PARTS, C, rounds,
                                          speeds=[1, 1, 1, 1, 2, 2, 3, 4])
        g = make("single")
        res = repro.run_query(
            repro.QuerySpec(g, schedule=sched,
                            emit="round_masked" if make is make_large
                            else "chunk"),
            shards)
        ref = repro.run_query(
            repro.QuerySpec(g, rounds=rounds, emit="round"), shards)
        print(f"  async+stragglers final matches: "
              f"{np.allclose(np.asarray(res.final), np.asarray(ref.final), rtol=1e-5)}")

    # Concurrent session (DESIGN.md §6): Q1 + Q6 + large-domain Q1 run as
    # ONE shared scan — engine.run_queries stacks them into a GLABundle and
    # every query's estimates come from the same single pass over the
    # shards, bitwise-identical to running each alone.
    print("\n=== concurrent session: Q1 + Q6 + Q1-large, one shared scan ===")
    session = {
        "Q1 group-by small": queries["Q1 group-by small"]("single"),
        "Q6 agg (low sel)": queries["Q6 agg (low sel)"]("single"),
        "Q1 group-by large": make_large("single"),
    }
    t0 = time.perf_counter()
    multi = repro.run_queries(
        repro.QuerySpec(list(session.values()), rounds=rounds, emit="round"),
        shards)
    jax.block_until_ready([r.final for r in multi])
    dt_shared = time.perf_counter() - t0
    t0 = time.perf_counter()
    solos = [repro.run_query(repro.QuerySpec(g, rounds=rounds, emit="round"),
                             shards)
             for g in session.values()]
    jax.block_until_ready([r.final for r in solos])
    dt_solo = time.perf_counter() - t0
    identical = all(
        np.asarray(m.final).tobytes() == np.asarray(s.final).tobytes()
        and all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                for a, b in zip((m.estimates.lower, m.estimates.upper),
                                (s.estimates.lower, s.estimates.upper)))
        for m, s in zip(multi, solos))
    print(f"  shared scan {dt_shared:6.2f}s vs 3 solo passes {dt_solo:6.2f}s"
          f"  (finals+bounds bitwise identical to solos: {identical})")
    for name, res in zip(session, multi):
        lo = np.asarray(res.estimates.lower, np.float64)
        hi = np.asarray(res.estimates.upper, np.float64)
        mid = np.asarray(res.estimates.estimate, np.float64)
        while mid.ndim > 2:
            lo, hi, mid = lo[..., 0], hi[..., 0], mid[..., 0]
        if mid.ndim == 2:  # group-by: busiest group
            gsel = int(np.argmax(np.abs(mid[-1])))
            lo, hi, mid = lo[:, gsel], hi[:, gsel], mid[:, gsel]
        w = (hi - lo) / np.maximum(np.abs(mid), 1e-12)
        print(f"  {name:18s} rel.width by round: "
              + " ".join(f"{x:.3f}" for x in w))
    assert identical, "shared scan diverged from solo runs"

    # Large-domain Q1 through the group-by Pallas kernel (DESIGN.md §3):
    # one ops.group_agg dispatch per round-slice instead of one segment_sum
    # per chunk, finals interchangeable with the scan path.
    print("\n=== Q1 group-by large: kernel dispatch (emit='kernel') ===")
    g = make_large("single")
    for emit in ("round", "kernel"):
        spec = repro.QuerySpec(g, rounds=rounds, emit=emit)
        t0 = time.perf_counter()
        res = repro.run_query(spec, shards)
        jax.block_until_ready(res.final)
        t1 = time.perf_counter()
        res = repro.run_query(spec, shards)
        jax.block_until_ready(res.final)
        dt = time.perf_counter() - t1
        print(f"  emit={emit:7s} compile+run {t1 - t0:6.2f}s  warm {dt:6.2f}s")
        if emit == "round":
            ref_final = np.asarray(res.final)
        else:
            k_final = np.asarray(res.final)
    identical = k_final.tobytes() == ref_final.tobytes()
    print(f"  kernel vs segment_sum finals bitwise identical: {identical}")
    assert np.allclose(k_final, ref_final, rtol=1e-5)
    # de-bucket the raw supplier domain from the bucket table (exact only
    # when the raw domain fits the bucket count; here 100k ids share 8192
    # buckets, so each bucket aggregates ~12 folded suppliers)
    deb = np.asarray(gla.debucket(jnp.asarray(ref_final),
                                  np.arange(SUPPLIERS), BUCKET_BITS))
    nz = int(np.count_nonzero(deb[:, 0] != 0.0))
    print(f"  de-bucketed table: {nz}/{SUPPLIERS} suppliers in non-empty "
          f"buckets, top bucket sum_qty={float(deb[:, 0].max()):.1f}")

    # Deep OLA (DESIGN.md §13): the composable plan-tree face of the same
    # engine.  A Q3-class two-table join (lineitem ⋈ orders, grouped by
    # the probed market segment) built as Scan→Filter→Join→GroupAgg runs
    # on the fused single-dispatch kernel — the probe tables ride into the
    # Pallas kernel as operands — bitwise-identical to the scan path.
    print("\n=== Deep OLA: Q3-class fused join (plan tree, emit='kernel') ===")
    segment, o_valid = tpch.orders_table(max(1, ROWS // 4), seed=12)
    join_tree = repro.GroupAgg(
        repro.Join(repro.Filter(repro.Scan(float(ROWS)), tpch.q1_cond),
                   lambda c: c["orderkey"], segment, o_valid),
        tpch.q6_func, num_groups=tpch.NUM_SEGMENTS)
    jspec = repro.QuerySpec(join_tree, rounds=rounds)
    a = repro.run_query(jspec.with_(emit="chunk"), shards)
    t0 = time.perf_counter()
    b = repro.run_query(jspec.with_(emit="kernel"), shards)
    jax.block_until_ready(b.final)
    dt = time.perf_counter() - t0
    identical = (np.asarray(a.final).tobytes() == np.asarray(b.final).tobytes()
                 and all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
                         for x, y in zip(jax.tree.leaves(a.snapshots),
                                         jax.tree.leaves(b.snapshots))))
    seg_sums = np.asarray(b.final).squeeze()
    print(f"  fused join {dt:6.2f}s  per-segment revenue: "
          + " ".join(f"{x:.0f}" for x in seg_sums))
    print(f"  kernel path bitwise identical to scan path: {identical}")
    assert identical, "fused join diverged from the scan path"

    # Nested aggregate: SUM over the segments whose *estimated* revenue
    # passes a HAVING threshold — the bounds can widen transiently when
    # the predicate flips a segment, so the UI-facing envelope is the
    # running intersection (repro.monotone_envelope): finite and
    # monotonically tightening by construction.
    print("\n=== Deep OLA: nested GROUP BY + HAVING, monotone envelope ===")
    having_tree = repro.Having(join_tree, threshold=float(seg_sums.mean()))
    res = repro.run_query(repro.QuerySpec(having_tree, rounds=rounds), shards)
    lo = np.asarray(res.estimates.lower, np.float64)
    hi = np.asarray(res.estimates.upper, np.float64)
    elo, ehi = map(np.asarray, repro.monotone_envelope(lo, hi))
    widths = ehi - elo
    print("  raw width by round:      "
          + " ".join(f"{x:.0f}" for x in (hi - lo)))
    print("  envelope width by round: "
          + " ".join(f"{x:.0f}" for x in widths))
    assert np.isfinite(widths).all(), "nested bounds must stay finite"
    assert (np.diff(widths) <= 1e-6).all(), "envelope must only tighten"

    # Sketch GLAs behind the same interface: COUNT DISTINCT (HLL-style
    # max monoid) and a median (additive DKW histogram), as plan trees.
    print("\n=== sketch GLAs: COUNT DISTINCT + median, same scan core ===")
    distinct_tree = repro.CountDistinct(repro.Scan(float(ROWS)),
                                        lambda c: c["suppkey"])
    res_d = repro.run_query(repro.QuerySpec(distinct_tree, rounds=rounds),
                            shards)
    exact_d = int(np.unique(np.asarray(cols["suppkey"])).size)
    est_d = float(res_d.final)
    print(f"  COUNT(DISTINCT suppkey): est {est_d:.0f} vs exact {exact_d} "
          f"({abs(est_d - exact_d) / exact_d:.2%} error)")
    assert abs(est_d - exact_d) / exact_d < 0.1
    qmax = float(np.asarray(cols["quantity"]).max())
    median_tree = repro.Quantile(repro.Scan(float(ROWS)),
                                 lambda c: c["quantity"], lo=0.0,
                                 hi=qmax + 1.0)
    res_q = repro.run_query(repro.QuerySpec(median_tree, rounds=rounds),
                            shards)
    exact_q = float(np.median(np.asarray(cols["quantity"])))
    q_lo = float(np.asarray(res_q.estimates.lower)[-1])
    q_hi = float(np.asarray(res_q.estimates.upper)[-1])
    print(f"  median(quantity): est {float(res_q.final):.2f} in DKW band "
          f"[{q_lo:.2f}, {q_hi:.2f}], exact {exact_q:.2f}")
    assert q_lo <= exact_q <= q_hi, "DKW band must contain the exact median"

    # Early termination (DESIGN.md §7): the incremental session driver
    # advances one round-slice at a time and stops the moment the CI meets
    # the rule — the paper's "stop as soon as the estimate is accurate
    # enough", with the un-scanned rounds actually never executed.
    print("\n=== early termination: stop at 1% relative error ===")
    # finer boundaries -> earlier possible stop (capped at one chunk/round)
    fine_rounds = min(4 * rounds, C)

    def wide_cond(c):
        return ((c["shipdate"] >= 0) & (c["shipdate"] < 1460)).astype(
            jnp.float32)

    q = gla.make_sum_gla(lambda c: c["quantity"], wide_cond,
                         d_total=float(ROWS))
    sess = repro.Session(
        repro.QuerySpec(q, rounds=fine_rounds, emit="chunk",
                        stop=repro.any_of(repro.rel_width(0.01),
                                          repro.budget(max_seconds=60.0))),
        shards)
    res = sess.run()
    est = res.estimates
    w = ((np.asarray(est.upper, np.float64)
          - np.asarray(est.lower, np.float64)) / 2.0
         / np.abs(np.asarray(est.estimate, np.float64)))
    print("  SUM(quantity), 4-year window; rel.width by round: "
          + " ".join(f"{x:.4f}" for x in w))
    frac = sess.steps_taken / sess.rounds_total
    print(f"  stopped at round {sess.steps_taken}/{sess.rounds_total} "
          f"(converged={sess.converged}) — scanned {frac:.1%} of the data, "
          f"saved {1 - frac:.1%} of the scan")
    final_full = repro.run_query(repro.QuerySpec(q, rounds=rounds),
                                 shards).final
    anytime = float(np.asarray(est.estimate)[-1])
    err = abs(anytime - float(final_full)) / abs(float(final_full))
    print(f"  anytime estimate {anytime:.0f} vs exact {float(final_full):.0f}"
          f" (actual error {err:.4%})")
    assert sess.steps_taken < sess.rounds_total, "expected an early stop"

    # Out-of-core scan (DESIGN.md §8): the same query over memory-mapped
    # .npy columns — one prefetched round-slice on device at a time, so
    # the scan is no longer capped by accelerator RAM — bitwise-identical
    # to the resident run.
    print("\n=== streaming source: out-of-core scan over .npy columns ===")
    import tempfile

    from repro.data import source as dsource

    with tempfile.TemporaryDirectory(prefix="tpch_ola_npy_") as td:
        src = dsource.NpyMmapSource(dsource.NpyMmapSource.save(shards, td))
        spec = repro.QuerySpec(q, rounds=rounds, emit="chunk")
        t0 = time.perf_counter()
        res_mem = repro.run_query(spec, shards)
        jax.block_until_ready(res_mem.final)
        dt_mem = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_str = repro.run_query(spec, src)
        jax.block_until_ready(res_str.final)
        dt_str = time.perf_counter() - t0
        identical = (np.asarray(res_str.final).tobytes()
                     == np.asarray(res_mem.final).tobytes())
        slice_frac = 1.0 / rounds
        print(f"  in-memory {dt_mem:6.2f}s vs streamed {dt_str:6.2f}s "
              f"(device holds ~{slice_frac:.0%} of the dataset per round)")
        print(f"  streamed final bitwise identical to resident: {identical}")
        assert identical, "streamed scan diverged from the resident run"


if __name__ == "__main__":
    main()
